"""Parameter structure: shapes + logical axes + init, from one declaration.

Every model declares its parameters once as a pytree of :class:`ParamSpec`.
From that single structure we derive:

- real initialised arrays (tests / training),
- ``jax.ShapeDtypeStruct`` stand-ins (dry-run lowering — no allocation),
- logical-axis trees that ``parallel.sharding`` maps to mesh PartitionSpecs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == rank
    dtype: Any = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones
    fan_in_axes: tuple[int, ...] | None = None  # dims contracted on use

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(f, structure):
    return jax.tree.map(f, structure, is_leaf=is_spec)


def shape_structs(structure):
    """ShapeDtypeStruct tree — what the dry-run lowers against."""
    return _tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), structure)


def axes_tree(structure):
    return _tree_map(lambda s: s.axes, structure)


def init_params(structure, key):
    """Materialise real parameters (smoke tests, the 100M-class train driver)."""
    leaves, treedef = jax.tree.flatten(structure, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            if spec.fan_in_axes:
                fan_in = int(np.prod([spec.shape[i] for i in spec.fan_in_axes]))
            else:
                fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * scale
                        ).astype(spec.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(structure) -> int:
    leaves = jax.tree.leaves(structure, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
