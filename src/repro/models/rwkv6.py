"""RWKV6 "Finch" time-mixing: linear attention with data-dependent decay.

TPU-native adaptation of the WKV6 recurrence: the GPU reference uses a
per-token CUDA kernel; here training/prefill run a *chunked-parallel* form —
within a chunk the recurrence is expressed as dense matmuls (MXU-friendly),
and the (head, Dk, Dv) state is carried across chunks by a scan.  The Pallas
kernel (kernels/rwkv6_scan.py) implements the same chunking with the state in
VMEM scratch and a sequential grid axis over chunks.

Recurrence (per head, per step t):
    a_t   = k_t ⊗ v_t                       (Dk, Dv)
    out_t = r_t @ (S_{t-1} + diag(u) a_t)   (Dv,)
    S_t   = diag(w_t) S_{t-1} + a_t
with w_t = exp(-exp(w0 + lora(x_t)))  — the data-dependent decay that defines
RWKV6.  (Token-shift mixing uses static lerp weights; Finch's ddlerp is an
orthogonal refinement, noted in DESIGN.md.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import constrain, rmsnorm
from .param import ParamSpec

LORA_RANK = 64
CHUNK = 32


def rwkv_specs(cfg: ModelConfig) -> dict:
    D, H, Dh = cfg.d_model, cfg.padded_heads, cfg.head_dim
    return {
        "mu_r": ParamSpec((D,), ("embed",), init="zeros"),
        "mu_k": ParamSpec((D,), ("embed",), init="zeros"),
        "mu_v": ParamSpec((D,), ("embed",), init="zeros"),
        "mu_w": ParamSpec((D,), ("embed",), init="zeros"),
        "mu_g": ParamSpec((D,), ("embed",), init="zeros"),
        "wr": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "w0": ParamSpec((H, Dh), ("heads", "head_dim"), dtype=jnp.float32, init="zeros"),
        "w_lora_a": ParamSpec((D, LORA_RANK), ("embed", None)),
        "w_lora_b": ParamSpec((LORA_RANK, H, Dh), (None, "heads", "head_dim")),
        "u": ParamSpec((H, Dh), ("heads", "head_dim"), dtype=jnp.float32, init="zeros"),
        "ln_x": ParamSpec((H, Dh), ("heads", "head_dim"), dtype=jnp.float32, init="ones"),
        "wo": ParamSpec((H, Dh, D), ("heads", "head_dim", "embed"), fan_in_axes=(0, 1)),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int):
    H, Dh = cfg.padded_heads, cfg.head_dim
    return {
        "s": jnp.zeros((batch, H, Dh, Dh), jnp.float32),   # wkv state
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
    }


def _projections(cfg, p, x, x_prev):
    """Token-shift lerps + r/k/v/g/w projections.  x: (B, S, D)."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)

    def mix(mu):
        return x + (shifted - x) * mu.astype(x.dtype)

    r = jnp.einsum("bsd,dhk->bshk", mix(p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,dhk->bshk", mix(p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mix(p["mu_v"]), p["wv"])
    g = jnp.einsum("bsd,dhk->bshk", mix(p["mu_g"]), p["wg"])
    xw = mix(p["mu_w"])
    lora = jnp.tanh(xw @ p["w_lora_a"])
    w_log = p["w0"] + jnp.einsum("bsr,rhk->bshk", lora, p["w_lora_b"]).astype(jnp.float32)
    log_decay = -jnp.exp(jnp.clip(w_log, -8.0, 4.0))           # in (-inf, 0)
    log_decay = jnp.maximum(log_decay, -8.0)                   # numerics floor
    return r, k, v, g, log_decay


def wkv_chunked(r, k, v, log_w, u, s0, chunk: int = CHUNK):  # noqa: C901
    """Chunked-parallel WKV6 scan.

    r/k/v: (B, S, H, Dh) ; log_w: (B, S, H, Dh) fp32 ; u: (H, Dh) ;
    s0: (B, H, Dk, Dv) fp32.  Returns (out (B,S,H,Dh), s_final).
    """
    B, S, H, Dh = r.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    split = lambda a: a.reshape(B, n, chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = split(r), split(k), split(v), split(log_w)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(s, blk):
        rb, kb, vb, wb = blk                                    # (B, c, H, Dh)
        rb32, kb32, vb32 = (a.astype(jnp.float32) for a in (rb, kb, vb))
        cw = jnp.cumsum(wb, axis=1)                             # (B, c, H, Dh) <= 0
        # inter-chunk: out_i += (r_i * exp(cw_{i-1})) @ s
        r_decayed = rb32 * jnp.exp(cw - wb)                     # exp(cw_{i-1})
        inter = jnp.einsum("bchk,bhkv->bchv", r_decayed, s)
        # intra-chunk: pairwise decay ratios exp(cw_{i-1} - cw_j), j < i
        expo = (cw - wb)[:, :, None] - cw[:, None, :, :]        # (B, c_i, c_j, H, Dh)
        expo = jnp.exp(jnp.clip(expo, -60.0, 0.0))
        att = jnp.einsum("bihk,bijhk,bjhk->bijh", rb32, expo, kb32)
        c = rb.shape[1]
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = att * tri[None, :, :, None]
        intra = jnp.einsum("bijh,bjhv->bihv", att, vb32)
        # bonus (current token): r_i . (u * k_i) * v_i
        bonus = (rb32 * u * kb32).sum(-1, keepdims=True) * vb32
        out = inter + intra + bonus
        # state update: s = diag(exp(cw_c)) s + sum_j exp(cw_c - cw_j) k_j v_j
        total = cw[:, -1]                                       # (B, H, Dh)
        k_scaled = kb32 * jnp.exp(total[:, None] - cw)
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bjhk,bjhv->bhkv", k_scaled, vb32)
        return s_new, out

    s_final, outs = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, Dh)[:, :S]
    return out, s_final


def wkv_step(r, k, v, log_w, u, s):
    """Single decode step.  r/k/v/log_w: (B, H, Dh); s: (B, H, Dk, Dv)."""
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    a = k32[..., :, None] * v32[..., None, :]                   # (B,H,Dk,Dv)
    out = jnp.einsum("bhk,bhkv->bhv", r32, s + u[..., None] * a)
    s_new = jnp.exp(log_w)[..., None] * s + a
    return out, s_new


def apply_rwkv(cfg: ModelConfig, p: dict, x: jax.Array, state: dict | None = None,
               *, decode: bool = False):
    """Time-mixing block body.  Returns (y, new_state)."""
    B, S, D = x.shape
    H, Dh = cfg.padded_heads, cfg.head_dim
    x_prev = state["x_prev"] if state is not None else jnp.zeros((B, D), x.dtype)
    r, k, v, g, log_w = _projections(cfg, p, x, x_prev)
    tpl = ("dp", None, "model", None)
    r, k, v, g = (constrain(a, cfg, tpl) for a in (r, k, v, g))
    log_w = constrain(log_w, cfg, tpl)
    u = p["u"]
    s0 = state["s"] if state is not None else jnp.zeros((B, H, Dh, Dh), jnp.float32)

    if decode:
        out, s_new = wkv_step(r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], u, s0)
        out = out[:, None]
    elif cfg.use_pallas:
        from ..kernels import ops as kops
        out, s_new = kops.rwkv6_scan(r, k, v, log_w, u, s0)
    else:
        out, s_new = wkv_chunked(r, k, v, log_w, u, s0, cfg.wkv_chunk)

    # per-head group norm, then output gate + projection
    out = out.reshape(B, S, H, Dh).astype(jnp.float32)
    var = jnp.mean(out * out, axis=-1, keepdims=True)
    out = out * jax.lax.rsqrt(var + cfg.rms_eps) * p["ln_x"]
    out = out.astype(x.dtype) * jax.nn.silu(g)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_state = {"s": s_new, "x_prev": x[:, -1, :].astype(jnp.bfloat16)}
    return y, new_state
