"""Shared neural building blocks (pure-functional, bf16-first)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .param import ParamSpec

try:                                    # jax >= 0.6 exports it at top level
    shard_map = jax.shard_map
except AttributeError:                  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map


def constrain(x: jax.Array, cfg, template: tuple) -> jax.Array:
    """Activation sharding constraint from a template of {"dp","model","sp",None}.

    "dp" shards over the data-parallel axes, "model" over the tensor-parallel
    axis, "sp" over "model" only when cfg.sp (sequence parallelism knob).
    Dims that don't divide evenly fall back to replicated.  No-op off-mesh.
    """
    mesh = cfg.mesh
    if mesh is None or mesh.size == 1:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if getattr(cfg, "dp_only", False) and "model" in mesh.axis_names:
        dp = dp + ("model",)           # pure-DP scheme: model axis joins DP
    dp_sz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    used_model = False
    parts = []
    for dim, t in zip(x.shape, template):
        if t == "dp" and dp and dim % dp_sz == 0:
            parts.append(dp)
        elif t in ("model", "sp") and not used_model \
                and (t == "model" or cfg.sp) \
                and not getattr(cfg, "dp_only", False) \
                and "model" in mesh.axis_names and dim % mesh.shape["model"] == 0:
            parts.append("model")
            used_model = True
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), ("embed",), dtype=jnp.float32, init="ones")


def tp_project_rs(h: jax.Array, w: jax.Array, cfg, *, contract_model_dims: int):
    """TP output projection with an explicit reduce-scatter (Megatron g-op).

    ``h``: activations whose model-sharded dims are contracted by ``w``
    (e.g. heads×head_dim, or the ffn hidden).  The plain einsum leaves a
    partial sum that GSPMD lowers to a full all-reduce (wire 2(n-1)/n·bytes);
    here shard_map computes the local partial and ``psum_scatter``s over the
    sequence dim (wire (n-1)/n·bytes — half), leaving the output in the
    sequence-parallel layout the next block consumes anyway.

    Falls back to the plain einsum when the mesh/shape doesn't allow it
    (decode Sq=1, replicated attention heads, no mesh).
    """
    mesh = cfg.mesh
    if contract_model_dims == 2:
        ein = "bshk,hkd->bsd"
        h_spec_dims = ("model", None)         # h: (B, S, H, Dh), H sharded
        w_spec = P("model", None, None)
    else:
        ein = "bsf,fd->bsd"
        h_spec_dims = ("model",)              # h: (B, S, F), F sharded
        w_spec = P("model", None)

    def plain_path():
        y = jnp.einsum(ein, h, w)
        return constrain(y, cfg, ("dp", "sp", None))

    if mesh is None or "model" not in getattr(mesh, "axis_names", ()) \
            or mesh.shape["model"] == 1 or not cfg.sp \
            or getattr(cfg, "tp_impl", "gspmd") != "shardmap":
        return plain_path()
    tp = mesh.shape["model"]
    S = h.shape[1]
    shard_dim_size = h.shape[2]
    if S % tp != 0 or shard_dim_size % tp != 0:
        return plain_path()
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B = h.shape[0]
    dp_sz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bdim = dp if (dp and B % dp_sz == 0) else None

    h_spec = P(bdim, None, *h_spec_dims)
    out_spec = P(bdim, "model", None)

    def local(hl, wl):
        y = jnp.einsum(ein, hl, wl)           # local partial sum
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1, tiled=True)

    return shard_map(local, mesh=mesh, in_specs=(h_spec, w_spec),
                     out_specs=out_spec)(h, w)


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for `positions` (any leading shape), half-dim layout."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, Dh); cos/sin: (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Gated-SiLU MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def geglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w1) * (x @ w3)
    return h @ w2


def mlp_specs(d_model: int, d_ff: int, prefix_axes=()) -> dict:
    """Gated MLP parameter structure (w1/w3 sharded on ffn, w2 on ffn-in)."""
    return {
        "w1": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w3": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w2": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }
