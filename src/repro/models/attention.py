"""Attention: GQA/MQA (+bias/qk_norm/window) and DeepSeek MLA.

All variants share one flash-style core: a KV-chunked online-softmax scan
(`_chunked_attend`) whose memory footprint is O(Sq * chunk) instead of
O(Sq * Sk) — this is what lets the 32k-prefill cells compile inside HBM on
the CPU backend, and it mirrors the Pallas kernel's block structure
(kernels/flash_attention.py) used on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, rmsnorm, rope_angles
from .param import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _mask(qpos, kpos, *, causal: bool, window: int, kv_valid):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= (qpos[:, None] - kpos[None, :]) < window
    if kv_valid is not None:
        m &= (kpos < kv_valid)[None, :]
    return m


def _direct_attend(q, k, v, qpos, kpos, *, causal, window, kv_valid, scale):
    """q: (B,Sq,KV,G,D); k/v: (B,Sk,KV,D)."""
    s = jnp.einsum("bqkgd,bckd->bqkgc", q, k).astype(jnp.float32) * scale
    m = _mask(qpos, kpos, causal=causal, window=window, kv_valid=kv_valid)
    s = jnp.where(m[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v)


def _chunked_attend(q, k, v, qpos, kpos, *, causal, window, kv_valid, scale,
                    kv_chunk: int):
    """Online-softmax scan over KV chunks (flash-attention recurrence).

    K and V head dims may differ (MLA: 192-dim keys, 128-dim values).
    """
    B, Sq, KV, G, Dk = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    n_chunks = -(-Sk // kv_chunk)
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=2 ** 30)  # never valid
    kc = k.reshape(B, n_chunks, kv_chunk, KV, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, Dv).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(n_chunks, kv_chunk)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, blk):
        # remat per KV chunk: backward recomputes the chunk's score/prob
        # matrices instead of saving S×S-worth of residuals across chunks.
        m, l, o = carry
        kb, vb, kp = blk
        s = jnp.einsum("bqkgd,bckd->bqkgc", q, kb).astype(jnp.float32) * scale
        msk = _mask(qpos, kp, causal=causal, window=window, kv_valid=kv_valid)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None]) * msk[None, :, None, None, :]
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        o = o * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l, o), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    o0 = jnp.zeros((B, Sq, KV, G, Dv), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kc, vc, pc))
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attend(q, k, v, qpos, kpos, *, causal=True, window=0, kv_valid=None,
           kv_chunk=1024, use_pallas=False):
    """Dispatch: Pallas kernel (TPU), direct (small), or chunked scan (long)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    Sq, Sk = q.shape[1], k.shape[1]
    if use_pallas and Sq > 1 and causal and window == 0 and kv_valid is None:
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, qpos, kpos, scale=scale)
    if Sq == 1 or Sk <= 2 * kv_chunk:
        return _direct_attend(q, k, v, qpos, kpos, causal=causal, window=window,
                              kv_valid=kv_valid, scale=scale)
    return _chunked_attend(q, k, v, qpos, kpos, causal=causal, window=window,
                           kv_valid=kv_valid, scale=scale, kv_chunk=kv_chunk)


# ---------------------------------------------------------------------------
# GQA / MQA module
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig, *, cross: bool = False) -> dict:
    D, H, KV, Dh = cfg.d_model, cfg.padded_heads, cfg.kv_heads_effective, cfg.head_dim
    s = {
        "wq": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, Dh, D), ("heads", "head_dim", "embed"),
                        fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((Dh,), ("head_dim",), dtype=jnp.float32, init="ones")
        s["k_norm"] = ParamSpec((Dh,), ("head_dim",), dtype=jnp.float32, init="ones")
    return s


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int):
    KV, Dh = cfg.kv_heads_effective, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, KV, Dh), jnp.bfloat16),
        "v": jnp.zeros((batch, max_len, KV, Dh), jnp.bfloat16),
    }


def apply_gqa(cfg: ModelConfig, p: dict, x: jax.Array, *, positions: jax.Array,
              kv_x: jax.Array | None = None, cross: bool = False,
              cache: dict | None = None, cache_index=None, kv_valid=None,
              causal: bool = True, window: int = 0, use_rope: bool = True):
    """Returns (output, updated_cache_or_None).

    - self-attention (cross=False): K/V from x; with `cache`, K/V are written
      at `cache_index` and attention runs against the cache (kv_valid masks).
    - cross-attention (cross=True): at prefill pass kv_x=encoder output (the
      projected K/V land in the returned cache); at decode pass kv_x=None to
      attend against the cached encoder K/V.
    """
    H, KV, Dh = cfg.padded_heads, cfg.kv_heads_effective, cfg.head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if cross and kv_x is None:
        k, v = cache["k"], cache["v"]  # decode-time cross attention
    else:
        src = kv_x if cross else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.rms_eps)
        if not (cross and kv_x is None):
            k = rmsnorm(p["k_norm"], k, cfg.rms_eps)
    if use_rope and not cross:
        cos, sin = rope_angles(positions, Dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = cache
    if cache is not None and not cross:
        idx = 0 if cache_index is None else cache_index
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(jnp.bfloat16), idx, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(jnp.bfloat16), idx, axis=1)
        new_cache = {"k": k_all, "v": v_all}
        k, v = k_all, v_all
    elif cross and kv_x is not None:
        new_cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)

    G = H // KV
    B, Sq = q.shape[0], q.shape[1]
    qg = q.reshape(B, Sq, KV, G, Dh)
    qpos = positions[0] if positions.ndim == 2 else positions
    out = attend(qg, k, v, qpos, kpos, causal=causal and not cross,
                 window=window, kv_valid=kv_valid, kv_chunk=cfg.attn_chunk,
                 use_pallas=cfg.use_pallas)
    out = out.reshape(B, Sq, H, Dh)
    from .layers import tp_project_rs
    y = tp_project_rs(out, p["wo"], cfg, contract_model_dims=2)
    return y, new_cache


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.padded_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": ParamSpec((D, H, qk), ("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((D, m.kv_lora_rank), ("embed", "lora")),
        "w_krope": ParamSpec((D, m.qk_rope_dim), ("embed", "head_dim")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("lora",), dtype=jnp.float32, init="ones"),
        # up-projections shard on HEADS, not lora: contracting a sharded lora
        # dim against the full cache costs a (B,T,H,D) all-reduce per step
        "w_uk": ParamSpec((m.kv_lora_rank, H, m.qk_nope_dim), (None, "heads", "head_dim")),
        "w_uv": ParamSpec((m.kv_lora_rank, H, m.v_head_dim), (None, "heads", "head_dim")),
        "wo": ParamSpec((H, m.v_head_dim, D), ("heads", "head_dim", "embed"),
                        fan_in_axes=(0, 1)),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.bfloat16),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_dim), jnp.bfloat16),
    }


def apply_mla(cfg: ModelConfig, p: dict, x: jax.Array, *, positions: jax.Array,
              cache: dict | None = None, cache_index=None, kv_valid=None):
    """MLA: KV compressed to rank-`kv_lora` latents + shared rope key.

    The cache stores only (c_kv, k_rope) — 576 floats/token vs 4096 for
    equivalent GQA — DeepSeek's KV-cache compression insight; decode
    reconstitutes per-head K/V through the up-projections.
    """
    m = cfg.mla
    H = cfg.padded_heads
    B, Sq, D = x.shape

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    cos, sin = rope_angles(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv = rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.rms_eps)
    krope = apply_rope((x @ p["w_krope"])[:, :, None, :], cos, sin)[:, :, 0, :]

    new_cache = cache
    if cache is not None:
        idx = 0 if cache_index is None else cache_index
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(jnp.bfloat16), idx, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(jnp.bfloat16), idx, axis=1)
        new_cache = {"ckv": ckv_all, "krope": kr_all}
        ckv, krope = ckv_all, kr_all

    # Reconstitute per-head keys/values from the latent cache.  Constrain to
    # head-sharded so the partitioner keeps the up-projection local per head
    # (contracting the sharded lora dim instead costs a (B,T,H,D) all-reduce
    # per layer per step — measured 2.3s on decode_32k).
    from .layers import constrain
    k_nope = constrain(jnp.einsum("btl,lhk->bthk", ckv, p["w_uk"]),
                       cfg, ("dp", None, "model", None))
    v = constrain(jnp.einsum("btl,lhk->bthk", ckv, p["w_uv"]),
                  cfg, ("dp", None, "model", None))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        krope[:, :, None, :], (*krope.shape[:2], H, m.qk_rope_dim))], axis=-1)

    qg = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]  # KV=H, G=1
    qpos = positions[0] if positions.ndim == 2 else positions
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    out = attend(qg.reshape(B, Sq, H, 1, -1), k, v, qpos, kpos, causal=True,
                 kv_valid=kv_valid, kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, Sq, H, m.v_head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
