"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

TPU-native adaptation: instead of GPU-style ragged grouped GEMMs, tokens are
scattered into a dense (experts, capacity, d_model) buffer and expert MLPs
run as one batched matmul on the MXU (the kernels/moe_gmm.py Pallas kernel
implements exactly this (E, C, D) x (E, D, F) contraction with VMEM tiling).
With experts sharded over the "model" axis the scatter/gather lowers to the
EP all-to-all pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import constrain, shard_map
from .param import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_ff
    s = {
        "router": ParamSpec((D, E), ("embed", "experts"), dtype=jnp.float32),
        "w1": ParamSpec((E, D, F), ("experts", "embed", "ffn")),
        "w3": ParamSpec((E, D, F), ("experts", "embed", "ffn")),
        "w2": ParamSpec((E, F, D), ("experts", "ffn", "embed")),
    }
    if m.num_shared_experts:
        Fs = m.d_ff * m.num_shared_experts
        s["shared_w1"] = ParamSpec((D, Fs), ("embed", "ffn"))
        s["shared_w3"] = ParamSpec((D, Fs), ("embed", "ffn"))
        s["shared_w2"] = ParamSpec((Fs, D), ("ffn", "embed"))
    return s


def capacity_of(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * n_tokens / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for TPU lane alignment


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, D) → (y, aux_loss).  Dispatches to the shard_map EP path on
    a real mesh (see _apply_moe_shardmap); the single-device scatter path
    below doubles as its correctness oracle."""
    mesh = cfg.mesh
    if (cfg.moe_impl in ("auto", "shardmap")
            and mesh is not None and "model" in getattr(mesh, "axis_names", ())
            and mesh.shape["model"] > 1
            and cfg.moe.num_experts % mesh.shape["model"] == 0):
        return _apply_moe_shardmap(cfg, p, x)
    return _apply_moe_local(cfg, p, x)


def _apply_moe_local(cfg: ModelConfig, p: dict, x: jax.Array):
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    E, K = m.num_experts, m.top_k
    C = capacity_of(cfg, N)
    xt = x.reshape(N, D)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (N, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (N, K, E)
    flat_oh = onehot.reshape(N * K, E)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=0) - flat_oh)    # (N*K, E)
    pos = (pos_in_expert * flat_oh).sum(-1).reshape(N, K)      # (N, K)
    keep = pos < C
    gate_vals = gate_vals * keep

    # Scatter tokens into the (E, C, D) dispatch buffer.
    e_flat = expert_idx.reshape(-1)
    pos_flat = jnp.where(keep, pos, C).reshape(-1)             # overflow -> C (dropped)
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    src = jnp.repeat(xt, K, axis=0) if K > 1 else xt
    buf = buf.at[e_flat, pos_flat].set(src)
    buf = buf[:, :C]                                           # (E, C, D)
    buf = constrain(buf, cfg, ("model", None, None))           # EP all-to-all

    # Batched expert MLP — the MXU-friendly (E, C, D) x (E, D, F) contraction.
    if cfg.use_pallas:
        from ..kernels import ops as kops
        hid = kops.moe_gmm(buf, p["w1"], p["w3"])
        out_buf = kops.moe_gmm_down(hid, p["w2"])
    else:
        hid = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
        out_buf = jnp.einsum("ecf,efd->ecd", hid, p["w2"])     # (E, C, D)

    # Gather back, weighted by gates.
    gathered = out_buf[e_flat, jnp.minimum(pos_flat, C - 1)]   # (N*K, D)
    y = (gathered.reshape(N, K, D) *
         gate_vals[..., None].astype(x.dtype)).sum(1)

    if m.num_shared_experts:
        h = jax.nn.silu(xt @ p["shared_w1"]) * (xt @ p["shared_w3"])
        y = y + h @ p["shared_w2"]

    # Switch-style load-balancing auxiliary loss.
    me = probs.mean(0)                                         # (E,)
    ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)       # fraction routed
    aux = (me * ce).sum() * E * m.aux_loss_coef
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# shard_map EP path (production mesh)
# ---------------------------------------------------------------------------
#
# On the (data, model) mesh, boundary activations are replicated over the
# "model" axis while experts are sharded over it.  Each device therefore
# already holds every token it could need: it routes locally, runs *its*
# E/tp experts on the tokens assigned to them, and one psum over "model"
# sums the partial expert outputs (the same collective pattern as TP-FFN).
# No dispatch all-to-all, no partitioner-inferred gathers — the naive
# scatter path costs ~100 GB/layer/device of involuntary all-gathers at
# deepseek scale (measured in the §Perf log); this path costs one
# (B_loc, S, D) all-reduce.

def _apply_moe_shardmap(cfg: ModelConfig, p: dict, x: jax.Array):
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = cfg.mesh
    tp = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    E_loc = E // tp

    x_spec = P(dp, None, None) if (dp and B % _dp_size(mesh) == 0) \
        else P(None, None, None)
    p_specs = {
        "router": P(),
        "w1": P("model", None, None),
        "w3": P("model", None, None),
        "w2": P("model", None, None),
    }
    if m.num_shared_experts:
        p_specs["shared_w1"] = P(None, "model")
        p_specs["shared_w3"] = P(None, "model")
        p_specs["shared_w2"] = P("model", None)

    def local_moe(p_loc, x_loc):
        Bl, Sl, _ = x_loc.shape
        N = Bl * Sl
        C = capacity_of(cfg, N)
        xt = x_loc.reshape(N, D)
        logits = xt.astype(jnp.float32) @ p_loc["router"]       # (N, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)
        flat_oh = onehot.reshape(N * K, E)
        pos = ((jnp.cumsum(flat_oh, axis=0) - flat_oh) * flat_oh).sum(-1)
        pos = pos.reshape(N, K)
        keep = pos < C
        gate_vals = gate_vals * keep

        # keep only the experts this model-rank owns
        j = jax.lax.axis_index("model")
        e_lo = j * E_loc
        mine = (expert_idx >= e_lo) & (expert_idx < e_lo + E_loc)
        e_local = jnp.clip(expert_idx - e_lo, 0, E_loc - 1)
        slot = jnp.where(mine & keep, pos, C)                   # C = drop slot

        buf = jnp.zeros((E_loc, C + 1, D), x_loc.dtype)
        src = jnp.repeat(xt, K, axis=0) if K > 1 else xt
        buf = buf.at[e_local.reshape(-1), slot.reshape(-1)].set(src)
        buf = buf[:, :C]

        if cfg.use_pallas:
            from ..kernels import ops as kops
            hid = kops.moe_gmm(buf, p_loc["w1"], p_loc["w3"])
            out_buf = kops.moe_gmm_down(hid, p_loc["w2"])
        else:
            hid = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p_loc["w1"])) \
                * jnp.einsum("ecd,edf->ecf", buf, p_loc["w3"])
            out_buf = jnp.einsum("ecf,efd->ecd", hid, p_loc["w2"])

        gathered = out_buf[e_local.reshape(-1),
                           jnp.minimum(slot.reshape(-1), C - 1)]
        w = (gate_vals * mine)[..., None].astype(x_loc.dtype)
        y = (gathered.reshape(N, K, D) * w).sum(1)

        if m.num_shared_experts:                # TP-sharded shared experts
            h = jax.nn.silu(xt @ p_loc["shared_w1"]) * (xt @ p_loc["shared_w3"])
            y = y + h @ p_loc["shared_w2"]
        y = jax.lax.psum(y, "model")            # sum partial expert outputs

        me = probs.mean(0)
        ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(0)
        aux = (me * ce).sum() * E * m.aux_loss_coef
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(Bl, Sl, D), aux

    fn = shard_map(local_moe, mesh=mesh,
                   in_specs=(p_specs, x_spec),
                   out_specs=(x_spec, P()))
    return fn(p, x)


def _dp_size(mesh) -> int:
    import numpy as _np
    return int(_np.prod([mesh.shape[a] for a in ("pod", "data")
                         if a in mesh.axis_names]))
