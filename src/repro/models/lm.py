"""Decoder-only LM assembly: pattern-based layer stack, scan + remat, caches.

Layer layout (general over all assigned archs):

    prefix layers   — unrolled (e.g. deepseek's first dense layer)
    scanned units   — `num_units` repeats of `block_pattern`, parameters
                      stacked on a leading "layers" axis, executed with
                      jax.lax.scan (+ jax.checkpoint for training) so the HLO
                      stays one-unit-sized regardless of depth
    suffix layers   — unrolled remainder (e.g. recurrentgemma's trailing 2)

Each layer = pre-norm mixing block (attn | mla | rwkv | rglru) + pre-norm
FFN block (dense MLP or MoE).  Caches/states mirror the params structure so
the same scan threads (params, cache) pairs during serving.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import rwkv6 as rwkv_lib
from .layers import constrain, mlp_specs, rmsnorm, rmsnorm_spec, swiglu
from .param import ParamSpec, is_spec


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def _layer_kind(cfg: ModelConfig, layer_idx: int) -> str:
    return cfg.block_pattern[layer_idx % cfg.repeat_unit]


def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.moe is not None and layer_idx >= cfg.moe.first_dense_layers


def _layer_specs(cfg: ModelConfig, kind: str, moe_layer: bool) -> dict:
    D = cfg.d_model
    s: dict[str, Any] = {"ln1": rmsnorm_spec(D), "ln2": rmsnorm_spec(D)}
    if kind == "attn":
        s["mix"] = attn_lib.mla_specs(cfg) if cfg.mla else attn_lib.gqa_specs(cfg)
    elif kind == "rwkv":
        s["mix"] = rwkv_lib.rwkv_specs(cfg)
    elif kind == "rglru":
        s["mix"] = rglru_lib.rglru_specs(cfg)
    else:
        raise ValueError(kind)
    s["ffn"] = moe_lib.moe_specs(cfg) if moe_layer else mlp_specs(D, cfg.d_ff)
    return s


def _stack(structure, n: int):
    return jax.tree.map(
        lambda p: ParamSpec((n,) + p.shape, ("layers",) + p.axes, p.dtype,
                            p.init, None if p.fan_in_axes is None
                            else tuple(i + 1 for i in p.fan_in_axes)),
        structure, is_leaf=is_spec)


def _partition(cfg: ModelConfig):
    """(prefix_idxs, scanned_idxs, suffix_idxs) over the layer range."""
    P = cfg.moe.first_dense_layers if cfg.moe else 0
    rest = cfg.num_layers - P
    U = rest // cfg.repeat_unit
    R = rest - U * cfg.repeat_unit
    prefix = list(range(P))
    scanned = list(range(P, P + U * cfg.repeat_unit))
    suffix = list(range(P + U * cfg.repeat_unit, cfg.num_layers))
    return prefix, scanned, suffix, U


def structure(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    prefix, scanned, suffix, U = _partition(cfg)
    s: dict[str, Any] = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), fan_in_axes=(1,)),
        "final_norm": rmsnorm_spec(D),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((V, D), ("vocab", "embed"))
    s["prefix"] = [
        _layer_specs(cfg, _layer_kind(cfg, i), _is_moe_layer(cfg, i)) for i in prefix]
    if U > 0:
        unit = {f"b{j}": _layer_specs(cfg, _layer_kind(cfg, scanned[0] + j),
                                      _is_moe_layer(cfg, scanned[0] + j))
                for j in range(cfg.repeat_unit)}
        s["unit"] = _stack(unit, U)
    s["suffix"] = [
        _layer_specs(cfg, _layer_kind(cfg, i), _is_moe_layer(cfg, i)) for i in suffix]
    return s


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind == "attn":
        if cfg.mla:
            return attn_lib.init_mla_cache(cfg, batch, max_len)
        length = min(max_len, cfg.window) if cfg.window else max_len
        c = attn_lib.init_kv_cache(cfg, batch, length)
        if cfg.window:
            c["pos"] = jnp.full((length,), -(2 ** 30), jnp.int32)
        return c
    if kind == "rwkv":
        return rwkv_lib.init_rwkv_state(cfg, batch)
    if kind == "rglru":
        return rglru_lib.init_rglru_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    prefix, scanned, suffix, U = _partition(cfg)
    cache: dict[str, Any] = {
        "prefix": [_layer_cache(cfg, _layer_kind(cfg, i), batch, max_len)
                   for i in prefix],
        "suffix": [_layer_cache(cfg, _layer_kind(cfg, i), batch, max_len)
                   for i in suffix],
    }
    if U > 0:
        unit = {f"b{j}": _layer_cache(cfg, _layer_kind(cfg, scanned[0] + j),
                                      batch, max_len)
                for j in range(cfg.repeat_unit)}
        cache["unit"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (U,) + x.shape), unit)
    return cache


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _window_cache_write(cfg, cache, k, v, positions):
    """Ring-buffer write for local attention; returns (cache', k_all, v_all, kpos)."""
    W = cache["k"].shape[1]
    S = k.shape[1]
    if S == 1:
        slot = positions[0, 0] % W
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(jnp.bfloat16), slot, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(jnp.bfloat16), slot, axis=1)
        pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions[0, :1], slot, axis=0)
    else:
        if S > W:
            k, v, positions = k[:, -W:], v[:, -W:], positions[:, -W:]
        slots = positions[0] % W
        k_all = cache["k"].at[:, slots].set(k.astype(jnp.bfloat16))
        v_all = cache["v"].at[:, slots].set(v.astype(jnp.bfloat16))
        pos = cache["pos"].at[slots].set(positions[0])
    return {"k": k_all, "v": v_all, "pos": pos}, k_all, v_all, pos


def _apply_attn(cfg, p, x, positions, cache, cache_index, kv_valid, decode):
    if cfg.mla:
        return attn_lib.apply_mla(cfg, p, x, positions=positions, cache=cache,
                                  cache_index=cache_index, kv_valid=kv_valid)
    window = cfg.window
    if cache is not None and window:
        # local attention with ring cache: project, rope, then ring write
        H, KV, Dh = cfg.padded_heads, cfg.kv_heads_effective, cfg.head_dim
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        from .layers import apply_rope, rope_angles
        cos, sin = rope_angles(positions, Dh, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        new_cache, k_all, v_all, kpos = _window_cache_write(cfg, cache, k, v, positions)
        B, Sq = q.shape[0], q.shape[1]
        qg = q.reshape(B, Sq, KV, H // KV, Dh)
        out = attn_lib.attend(qg, k_all, v_all, positions[0], kpos,
                              causal=True, window=window, kv_valid=kv_valid,
                              kv_chunk=cfg.attn_chunk)
        y = jnp.einsum("bshk,hkd->bsd", out.reshape(B, Sq, H, Dh), p["wo"])
        return y, new_cache
    return attn_lib.apply_gqa(cfg, p, x, positions=positions, cache=cache,
                              cache_index=cache_index, kv_valid=kv_valid,
                              window=window)


def _apply_layer(cfg, kind, moe_layer, p, x, positions, cache, cache_index,
                 kv_valid, decode):
    h = rmsnorm(p["ln1"], x, cfg.rms_eps)
    if kind == "attn":
        mix, new_cache = _apply_attn(cfg, p["mix"], h, positions, cache,
                                     cache_index, kv_valid, decode)
    elif kind == "rwkv":
        mix, new_cache = rwkv_lib.apply_rwkv(cfg, p["mix"], h, cache, decode=decode)
    else:
        mix, new_cache = rglru_lib.apply_rglru(cfg, p["mix"], h, cache, decode=decode)
    # Megatron-SP: constrain each block OUTPUT to the sequence-sharded layout
    # so the TP partial-sum lowers to reduce-scatter instead of all-reduce
    # (the all-gather on the next block's input is paid either way).
    mix = constrain(mix, cfg, ("dp", "sp", None))
    x = x + mix
    h = rmsnorm(p["ln2"], x, cfg.rms_eps)
    if moe_layer:
        ffn, aux = moe_lib.apply_moe(cfg, p["ffn"], h)
    else:
        from .layers import tp_project_rs
        hid = jax.nn.silu(h @ p["ffn"]["w1"]) * (h @ p["ffn"]["w3"])
        ffn = tp_project_rs(hid, p["ffn"]["w2"], cfg, contract_model_dims=1)
        aux = 0.0
    x = x + ffn
    # boundary residual: DP batch + (optionally) sequence-parallel over model
    x = constrain(x, cfg, ("dp", "sp", None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, prefix_embeds):
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(jnp.bfloat16)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(cfg, params, x):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", x, head)


def _run_stack(cfg, params, x, positions, caches, cache_index, kv_valid,
               decode, train):
    prefix, scanned, suffix, U = _partition(cfg)
    aux_total = 0.0
    new_caches: dict[str, Any] = {"prefix": [], "suffix": []}

    for n, i in enumerate(prefix):
        c = caches["prefix"][n] if caches else None
        x, nc, aux = _apply_layer(cfg, _layer_kind(cfg, i), _is_moe_layer(cfg, i),
                                  params["prefix"][n], x, positions, c,
                                  cache_index, kv_valid, decode)
        new_caches["prefix"].append(nc)
        aux_total += aux

    if U > 0:
        kinds = [_layer_kind(cfg, scanned[0] + j) for j in range(cfg.repeat_unit)]
        moes = [_is_moe_layer(cfg, scanned[0] + j) for j in range(cfg.repeat_unit)]

        def unit_body(carry, xs):
            x, aux = carry
            p_unit, c_unit = xs
            new_c = {}
            for j, (kind, moe_l) in enumerate(zip(kinds, moes)):
                c = c_unit[f"b{j}"] if c_unit is not None else None
                x, nc, a = _apply_layer(cfg, kind, moe_l, p_unit[f"b{j}"], x,
                                        positions, c, cache_index, kv_valid, decode)
                new_c[f"b{j}"] = nc
                aux = aux + a
            return (x, aux), new_c

        body = unit_body
        if train and cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(unit_body, policy=policy)
        if not cfg.use_scan:
            # unrolled path (roofline cost compiles: exact per-unit marginals)
            carry = (x, aux_total)
            outs = []
            for u in range(U):
                p_u = jax.tree.map(lambda a: a[u], params["unit"])
                c_u = (jax.tree.map(lambda a: a[u], caches["unit"])
                       if caches else None)
                carry, nc = body(carry, (p_u, c_u))
                outs.append(nc)
            x, aux_total = carry
            new_caches["unit"] = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                                  if caches else None)
        elif caches is None:
            def body_nocache(carry, p_unit):
                new_carry, _ = body(carry, (p_unit, None))
                return new_carry, None
            (x, aux_total), _ = jax.lax.scan(body_nocache, (x, aux_total), params["unit"])
            new_caches["unit"] = None
        else:
            xs = (params["unit"], caches["unit"])
            (x, aux_total), unit_caches = jax.lax.scan(body, (x, aux_total), xs)
            new_caches["unit"] = unit_caches

    for n, i in enumerate(suffix):
        c = caches["suffix"][n] if caches else None
        x, nc, aux = _apply_layer(cfg, _layer_kind(cfg, i), _is_moe_layer(cfg, i),
                                  params["suffix"][n], x, positions, c,
                                  cache_index, kv_valid, decode)
        new_caches["suffix"].append(nc)
        aux_total += aux

    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return x, new_caches, aux_total


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None, *, train=True):
    """Full-sequence forward (training / evaluation).  Returns (logits, aux)."""
    x, aux = forward_hidden(cfg, params, tokens, prefix_embeds, train=train)
    return _logits(cfg, params, x), aux


def forward_hidden(cfg: ModelConfig, params, tokens, prefix_embeds=None, *,
                   train=True):
    """Forward up to the final norm (pre-logits) — the fused-CE entry point."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, aux = _run_stack(cfg, params, x, positions, None, None, None,
                           decode=False, train=train)
    return x, aux


def lm_head_weights(cfg: ModelConfig, params):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def prefill(cfg: ModelConfig, params, tokens, cache, prefix_embeds=None):
    """Populate caches from a prompt; returns (last-position logits, cache)."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, new_cache, _ = _run_stack(cfg, params, x, positions, cache, 0,
                                 jnp.int32(S), decode=False, train=False)
    return _logits(cfg, params, x[:, -1:]), new_cache


def decode_step(cfg: ModelConfig, params, token, cache, index):
    """One decode step.  token: (B, 1) int32; index: scalar int32 position."""
    x = _embed_inputs(cfg, params, token, None)
    B = x.shape[0]
    positions = jnp.broadcast_to(index.astype(jnp.int32)[None, None], (B, 1))
    x, new_cache, _ = _run_stack(cfg, params, x, positions, cache, index,
                                 index + 1, decode=True, train=False)
    return _logits(cfg, params, x), new_cache
