"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(x_t @ Wa)                    (recurrence gate)
    i_t = sigmoid(x_t @ Wx)                    (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)          (data-dependent diagonal decay)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the diagonal linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly — the hardware
adaptation of the GPU sequential kernel); the Pallas kernel
(kernels/rglru_scan.py) provides a chunked VMEM variant.  Decode keeps
(h, conv window) state.  Channels are fully independent, so the "rnn" width
axis shards cleanly over the model axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import constrain
from .param import ParamSpec

C_CONST = 8.0


def rglru_specs(cfg: ModelConfig) -> dict:
    D, R, W = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return {
        "w_in": ParamSpec((D, R), ("embed", "rnn")),
        "w_gate_branch": ParamSpec((D, R), ("embed", "rnn")),
        "conv_w": ParamSpec((W, R), (None, "rnn")),
        "conv_b": ParamSpec((R,), ("rnn",), init="zeros"),
        "wa": ParamSpec((R, R), ("rnn", None)),
        "ba": ParamSpec((R,), ("rnn",), init="zeros"),
        "wx": ParamSpec((R, R), ("rnn", None)),
        "bx": ParamSpec((R,), ("rnn",), init="zeros"),
        "lam": ParamSpec((R,), ("rnn",), dtype=jnp.float32, init="ones"),
        "w_out": ParamSpec((R, D), ("rnn", "embed")),
    }


def init_rglru_state(cfg: ModelConfig, batch: int):
    R, W = cfg.rnn_width, cfg.conv_width
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, R), jnp.bfloat16),
    }


def _gates(p, u):
    """u: (..., R) post-conv activations → (log_a, gated input)."""
    r = jax.nn.sigmoid((u @ p["wa"] + p["ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["wx"] + p["bx"]).astype(jnp.float32))
    log_a = -C_CONST * jax.nn.softplus(p["lam"]) * r            # (..., R) < 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    x_in = beta * i * u.astype(jnp.float32)
    return log_a, x_in


def rglru_scan(log_a, x_in, h0):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + x_t via associative scan.

    log_a/x_in: (B, S, R) fp32; h0: (B, R) fp32.
    """
    # Fold h0 into the first element: h_1 = a_1 h_0 + x_1.
    x_in = x_in.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        la1, y1 = c1
        la2, y2 = c2
        return la1 + la2, jnp.exp(la2) * y1 + y2

    la, h = jax.lax.associative_scan(combine, (log_a, x_in), axis=1)
    return h


def rglru_chunked(log_a, x_in, h0, chunk: int):
    """Chunked recurrence: inner associative scan, outer sequential carry.

    Bounds the log-depth scan's materialised intermediates to O(chunk)
    instead of O(S) — the memory fix that lets train_4k/prefill_32k cells
    fit HBM (the Pallas kernel mirrors this chunking in VMEM).
    """
    B, S, R = x_in.shape
    if S <= chunk:
        hs = rglru_scan(log_a, x_in, h0)
        return hs, hs[:, -1]
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0)))
    la_c = log_a.reshape(B, n, chunk, R).transpose(1, 0, 2, 3)
    xi_c = x_in.reshape(B, n, chunk, R).transpose(1, 0, 2, 3)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(h, blk):
        la, xi = blk
        hs = rglru_scan(la, xi, h)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(body, h0, (la_c, xi_c))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, n * chunk, R)[:, :S]
    return hs, h_last


def apply_rglru(cfg: ModelConfig, p: dict, x: jax.Array, state: dict | None = None,
                *, decode: bool = False):
    """Griffin recurrent block body: conv1d → RG-LRU → gate → out-proj."""
    B, S, D = x.shape
    W = cfg.conv_width
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    u = x @ p["w_in"]                                           # (B, S, R)
    gate = constrain(gate, cfg, ("dp", None, "model"))
    u = constrain(u, cfg, ("dp", None, "model"))

    prev = state["conv"] if state is not None else jnp.zeros(
        (B, W - 1, u.shape[-1]), u.dtype)
    seq = jnp.concatenate([prev.astype(u.dtype), u], axis=1)    # (B, S+W-1, R)
    # depthwise causal conv, width W
    conv = sum(seq[:, i:i + S] * p["conv_w"][i] for i in range(W)) + p["conv_b"]

    log_a, x_in = _gates(p, conv)
    log_a = constrain(log_a, cfg, ("dp", None, "model"))
    x_in = constrain(x_in, cfg, ("dp", None, "model"))
    h0 = state["h"] if state is not None else jnp.zeros((B, u.shape[-1]), jnp.float32)
    if decode:
        h = jnp.exp(log_a[:, 0]) * h0 + x_in[:, 0]
        hs = h[:, None]
        h_last = h
    elif cfg.use_pallas:
        from ..kernels import ops as kops
        hs, h_last = kops.rglru_scan(log_a, x_in, h0)
    else:
        hs, h_last = rglru_chunked(log_a, x_in, h0, cfg.rglru_chunk)
    hs = constrain(hs, cfg, ("dp", None, "model"))

    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    new_state = {"h": h_last, "conv": seq[:, -(W - 1):].astype(jnp.bfloat16)}
    return y, new_state
