"""Model facade: family dispatch + input specs for every (arch x shape) cell."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, lm
from .param import count_params, init_params, shape_structs


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- structure -----------------------------------------------------
    def structure(self):
        return (encdec if self.cfg.encdec else lm).structure(self.cfg)

    def shape_structs(self):
        return shape_structs(self.structure())

    def init(self, key):
        return init_params(self.structure(), key)

    def num_params(self) -> int:
        return count_params(self.structure())

    def init_cache(self, batch: int, max_len: int):
        return (encdec if self.cfg.encdec else lm).init_cache(self.cfg, batch, max_len)

    # -- compute -------------------------------------------------------
    def forward(self, params, batch, *, train=True):
        """batch: dict from input_specs(kind='train').  Returns (logits, aux)."""
        if self.cfg.encdec:
            return encdec.forward(self.cfg, params, batch["tokens"],
                                  batch["frames"], train=train)
        return lm.forward(self.cfg, params, batch["tokens"],
                          batch.get("prefix_embeds"), train=train)

    def prefill(self, params, batch, cache):
        if self.cfg.encdec:
            return encdec.prefill(self.cfg, params, batch["tokens"],
                                  batch["frames"], cache)
        return lm.prefill(self.cfg, params, batch["tokens"], cache,
                          batch.get("prefix_embeds"))

    def decode_step(self, params, token, cache, index):
        mod = encdec if self.cfg.encdec else lm
        return mod.decode_step(self.cfg, params, token, cache, index)

    # -- input specs (ShapeDtypeStruct stand-ins, no allocation) --------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """Inputs for the given shape cell's step function.

        Modality frontends are STUBS: vision/audio cells receive precomputed
        patch/frame embeddings as inputs, per the task spec.
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        emb = lambda b, s: jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)

        if shape.kind == "train":
            if cfg.encdec:
                return {"tokens": tok(B, S), "labels": tok(B, S),
                        "frames": emb(B, cfg.frontend_len)}
            if cfg.frontend == "vision":
                s_text = S - cfg.frontend_len
                return {"tokens": tok(B, s_text), "labels": tok(B, s_text),
                        "prefix_embeds": emb(B, cfg.frontend_len)}
            return {"tokens": tok(B, S), "labels": tok(B, S)}
        if shape.kind == "prefill":
            if cfg.encdec:
                return {"tokens": tok(B, S), "frames": emb(B, cfg.frontend_len)}
            if cfg.frontend == "vision":
                return {"tokens": tok(B, S - cfg.frontend_len),
                        "prefix_embeds": emb(B, cfg.frontend_len)}
            return {"tokens": tok(B, S)}
        # decode: one new token against a seq_len-deep cache
        return {"token": tok(B, 1)}

    def realize_inputs(self, shape: ShapeConfig, key) -> dict:
        """Concrete random inputs matching input_specs (smoke tests)."""
        specs = self.input_specs(shape)
        out = {}
        for name, s in specs.items():
            key, k = jax.random.split(key)
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k, s.shape, 0, self.cfg.vocab_size, jnp.int32)
            else:
                out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
        return out


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
