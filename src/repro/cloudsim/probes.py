"""Probing-based availability & interruption experiments (paper §6 methodology).

Implements the Wu et al. / Li et al. measurement protocol the paper adopts:
instead of keeping fleets running, periodically issue lightweight spot
requests, record success/failure, and (for survival experiments) launch and
track node lifetimes until reclaim.

- ``probe_real_availability``: the ground-truth *Real Availability Score*
  (fraction of successful n-node requests over the probing horizon).
- ``run_interruption_experiment``: launches pools and advances market time,
  yielding per-node (duration, event) pairs for Kaplan-Meier / Cox analyses.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .market import SpotMarket


@dataclass
class ProbeResult:
    target: tuple[str, str, str]     # (type, region, az)
    successes: int
    attempts: int

    @property
    def real_availability(self) -> float:
        return 100.0 * self.successes / max(self.attempts, 1)


def probe_real_availability(market: SpotMarket, targets, n_nodes: int = 50, *,
                            period_min: float = 10.0, duration_min: float = 1440.0,
                            launch: bool = False) -> list[ProbeResult]:
    """Send an n-node request for every target every `period_min` minutes."""
    results = {t: ProbeResult(t, 0, 0) for t in targets}
    t_end = market.now + duration_min
    while market.now < t_end:
        for tgt in targets:
            ok, ids = market.request_spot(*tgt, n_nodes, launch=launch)
            res = results[tgt]
            res.attempts += 1
            res.successes += int(ok)
            if ids:
                market.terminate(ids)  # launch-and-scoot: measure, don't hold
        market.advance(market.now + period_min)
    return list(results.values())


@dataclass
class LifetimeData:
    durations: np.ndarray   # minutes alive
    events: np.ndarray      # 1 = interrupted, 0 = censored (survived horizon)
    covariates: np.ndarray  # per-node covariate (e.g. availability score)


def run_interruption_experiment(market: SpotMarket, pools, scores, *,
                                n_nodes: int = 10, horizon_min: float = 1440.0,
                                relaunch: bool = True,
                                relaunch_period_min: float = 60.0) -> LifetimeData:
    """Launch `n_nodes` on each pool, run the market, record lifetimes.

    `pools` : list of (type, region, az); `scores`: matching covariate values.
    With `relaunch`, reclaimed capacity is re-requested every relaunch period —
    the paper's continuous-experiment protocol — otherwise one-shot.
    """
    node_score: dict[int, float] = {}
    for tgt, sc in zip(pools, scores):
        ok, ids = market.request_spot(*tgt, n_nodes)
        for nid in ids:
            node_score[nid] = sc

    t_end = market.now + horizon_min
    next_relaunch = market.now + relaunch_period_min
    while market.now < t_end:
        step_to = min(t_end, next_relaunch)
        market.advance(step_to)
        if relaunch and market.now >= next_relaunch and market.now < t_end:
            for tgt, sc in zip(pools, scores):
                i = market.pool_index[(tgt[0], tgt[1], tgt[2])]
                alive = len(market._alive_by_pool.get(i, []))
                missing = n_nodes - alive
                if missing > 0:
                    ok, ids = market.request_spot(*tgt, missing)
                    for nid in ids:
                        node_score[nid] = sc
            next_relaunch += relaunch_period_min

    durations, events, covs = [], [], []
    for rec in market.records:
        if rec.node_id not in node_score:
            continue
        end = rec.end_t if rec.end_t is not None else t_end
        durations.append(end - rec.launch_t)
        events.append(1 if rec.reason == "interrupted" else 0)
        covs.append(node_score[rec.node_id])
    return LifetimeData(np.asarray(durations, np.float64),
                        np.asarray(events, np.int64),
                        np.asarray(covs, np.float64))
