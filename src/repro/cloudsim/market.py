"""Spot-market simulator: capacity pools, SPS semantics, interruptions.

Stands in for the vendor's spot backend.  Each (instance type, AZ) pair owns a
shared capacity pool (the paper's §2 model: "instances of the same type within
an AZ are provisioned from a shared capacity pool").  Capacity follows a
deterministic seeded process calibrated to the paper's measurements:

- daily cycle peaking at local nighttime, dipping during business hours
  (§6.2, Fig. 6), with weekly modulation;
- strongly skewed base-capacity distribution (scarce / moderate / plentiful
  mixture) so the T3 distribution over the USQS grid has entropy ≈ 2.5 bits
  (§3.1.1) and a J-shaped 24h-sustain curve with a 50-cap ceiling effect
  (Fig. 10);
- per-AZ base factors giving >1/3 of types a max-min T3 spread of ~50 across
  AZs (Fig. 9);
- family-level phase/amplitude sharing so adjacent sizes correlate (Fig. 7);
- an "azure" profile with weak seasonality, dominant trend, amplitude regime
  shifts and missing query responses (§6.2, Table 1, §8);
- a "gcp" profile between the two: moderate seasonality, mild trend, higher
  noise, no missing responses (preemption stats are published, not sampled).

Multi-vendor worlds pass ``vendor=`` so every deterministic draw — pool
parameters, missing-response coin flips, reclaim victim selection — is salted
by ``(seed, profile, vendor)``.  Two regions built from structurally identical
configs therefore never replay the same capacity trace.  ``vendor=None``
(default) keeps the historical key shape, so committed benchmark artifacts
stay bit-identical.

SPS semantics: for a request of n nodes against free capacity f,
SPS = 3 if f >= n, 2 if f >= ceil(n/2), else 1 — monotone non-increasing in n
by construction (the property TSTP exploits).  T3_true = clip(floor(f), 0, 50).

Interruptions: when a pool's capacity drops below its committed usage, excess
nodes are reclaimed (seeded-random victims), emitting interruption events with
full lifetimes for the survival analyses.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from .catalog import Catalog, InstanceType, REGION_UTC_OFFSET

MINUTES_PER_DAY = 1440
MINUTES_PER_WEEK = 10080
SPS_CAP = 50  # vendor query cap on node count

# Irrational-ish periods (minutes) for the smooth deterministic noise field.
_NOISE_PERIODS = np.array([73.3, 211.7, 487.9, 1013.1])


def _hash_units(key: str, n: int) -> np.ndarray:
    """n deterministic uniforms in [0,1) from a string key."""
    out = np.empty(n)
    for i in range(n):
        h = hashlib.blake2b(f"{key}:{i}".encode(), digest_size=8).digest()
        out[i] = int.from_bytes(h, "little") / 2.0 ** 64
    return out


@dataclass
class NodeRecord:
    node_id: int
    pool_idx: int
    launch_t: float
    end_t: float | None = None
    reason: str | None = None   # "interrupted" | "terminated"

    @property
    def alive(self) -> bool:
        return self.end_t is None


@dataclass
class PoolKey:
    type_name: str
    region: str
    az: str

    def __hash__(self):
        return hash((self.type_name, self.region, self.az))


class SpotMarket:
    """Deterministic, seeded spot-market simulator."""

    def __init__(self, catalog: Catalog, seed: int = 0, profile: str = "aws",
                 *, vendor: str | None = None):
        assert profile in ("aws", "azure", "gcp")
        self.catalog = catalog
        self.seed = seed
        self.profile = profile
        self.vendor = vendor
        self.now = 0.0  # minutes
        self._records: list[NodeRecord] = []
        self._alive_by_pool: dict[int, list[int]] = {}
        if vendor is None:
            rng_seed = seed ^ 0x5F0CAFE
        else:
            # (seed, vendor, region set) → independent victim-selection
            # streams per region world, stable across process restarts
            key = f"{seed}:{vendor}:{','.join(sorted(catalog.regions))}"
            digest = hashlib.blake2b(key.encode(), digest_size=8).digest()
            rng_seed = int.from_bytes(digest, "little")
        self._rng = np.random.default_rng(rng_seed)
        #: append-only interruption event log.  ``advance`` (capacity-driven
        #: reclaims) and :meth:`reclaim` (targeted chaos reclaims) both append
        #: here, so a consumer that missed an ``advance`` return value — the
        #: operator's reconcile loop observes the market, it does not drive
        #: it — can still replay every event via :meth:`events_since`.
        self.interruptions: list[NodeRecord] = []

        pools = catalog.pools()
        self.pool_keys: list[tuple[InstanceType, str, str]] = pools
        self.pool_index: dict[tuple[str, str, str], int] = {
            (t.name, r, az): i for i, (t, r, az) in enumerate(pools)
        }
        P = len(pools)
        self._used = np.zeros(P)

        # ---- deterministic per-pool process parameters -------------------
        base = np.empty(P)
        daily_amp = np.empty(P)
        weekly_amp = np.empty(P)
        daily_phase = np.empty(P)
        weekly_phase = np.empty(P)
        trend = np.empty(P)
        noise_amp = np.empty(P)
        noise_phase = np.empty((P, len(_NOISE_PERIODS)))
        regime_amp = np.ones(P)       # azure amplitude regime-shift factor
        regime_period = np.full(P, np.inf)

        s = f"{seed}:{profile}" if vendor is None else f"{seed}:{profile}:{vendor}"
        self._salt = s
        for i, (t, r, az) in enumerate(pools):
            fam_key = f"{s}:fam:{t.family}:{az}"
            u_fam = _hash_units(fam_key, 4)
            u_pool = _hash_units(f"{s}:pool:{t.name}:{az}", 8)

            # Base capacity: skewed mixture at (family, az) level, shaped by size.
            mix = u_fam[0]
            if mix < 0.30:
                fam_base = 6.0 * u_fam[1]                       # scarce
            elif mix < 0.62:
                fam_base = 10.0 + 60.0 * u_fam[1]               # moderate
            else:
                fam_base = 80.0 + 180.0 * u_fam[1]              # plentiful
            size_factor = (8.0 / t.vcpus) ** 0.45               # small sizes more plentiful
            base[i] = fam_base * size_factor * (0.8 + 0.4 * u_pool[0])

            offset_min = catalog.utc_offset(r) * 60.0
            if profile == "aws":
                daily_amp[i] = 0.25 + 0.35 * u_fam[2]
                weekly_amp[i] = 0.03 + 0.07 * u_pool[1]
                trend[i] = (u_pool[2] - 0.5) * 2e-6 * base[i]
                noise_amp[i] = 0.02 + 0.06 * u_pool[3]
            elif profile == "gcp":  # moderate seasonality, mild trend, noisy
                daily_amp[i] = 0.15 + 0.20 * u_fam[2]
                weekly_amp[i] = 0.02 + 0.05 * u_pool[1]
                trend[i] = (u_pool[2] - 0.5) * 8e-6 * base[i]
                noise_amp[i] = 0.05 + 0.10 * u_pool[3]
            else:  # azure: weak seasonality, strong trend, regime shifts, noise
                daily_amp[i] = 0.02 + 0.10 * u_fam[2]
                weekly_amp[i] = 0.02 + 0.05 * u_pool[1]
                trend[i] = (u_pool[2] - 0.45) * 6e-5 * base[i]
                noise_amp[i] = 0.10 + 0.20 * u_pool[3]
                regime_amp[i] = 0.3 + 0.9 * u_pool[6]
                regime_period[i] = MINUTES_PER_WEEK * (2.0 + 6.0 * u_pool[7])
            # Nighttime peak ~03:00 local, family-synchronised phase jitter.
            daily_phase[i] = (180.0 - offset_min + 60.0 * (u_fam[3] - 0.5))
            weekly_phase[i] = MINUTES_PER_WEEK * u_pool[4]
            noise_phase[i] = 2 * np.pi * _hash_units(f"{s}:noise:{t.name}:{az}", len(_NOISE_PERIODS))

        self._base = base
        self._daily_amp = daily_amp
        self._weekly_amp = weekly_amp
        self._daily_phase = daily_phase
        self._weekly_phase = weekly_phase
        self._trend = trend
        self._noise_amp = noise_amp
        self._noise_phase = noise_phase
        self._regime_amp = regime_amp
        self._regime_period = regime_period
        self._missing_rate = 0.05 if profile == "azure" else 0.0

    # ------------------------------------------------------------------
    # capacity field
    # ------------------------------------------------------------------

    def capacity(self, t: float, idx: np.ndarray | None = None) -> np.ndarray:
        """Deterministic capacity of pools `idx` (all pools if None) at time t."""
        if idx is None:
            idx = slice(None)
        b = self._base[idx]
        daily = self._daily_amp[idx] * np.cos(
            2 * np.pi * (t - self._daily_phase[idx]) / MINUTES_PER_DAY)
        if self.profile == "azure":
            # amplitude regime shifts (square-wave modulation of the seasonal term)
            regime = np.where(
                np.sin(2 * np.pi * t / self._regime_period[idx]) > 0,
                1.0, self._regime_amp[idx])
            daily = daily * regime
        weekly = self._weekly_amp[idx] * np.cos(
            2 * np.pi * (t - self._weekly_phase[idx]) / MINUTES_PER_WEEK)
        noise = self._noise_amp[idx] * np.sin(
            2 * np.pi * t / _NOISE_PERIODS[None, :] + self._noise_phase[idx]
        ).sum(-1) / np.sqrt(len(_NOISE_PERIODS))
        cap = b * (1.0 + daily + weekly + noise) + self._trend[idx] * t
        return np.maximum(cap, 0.0)

    def free(self, t: float, idx: np.ndarray | None = None) -> np.ndarray:
        if idx is None:
            used = self._used
        else:
            used = self._used[idx]
        return np.maximum(self.capacity(t, idx) - used, 0.0)

    # ------------------------------------------------------------------
    # vendor APIs
    # ------------------------------------------------------------------

    def _pool_idx(self, type_name: str, region: str, az: str) -> int:
        return self.pool_index[(type_name, region, az)]

    def sps(self, type_name: str, region: str, az: str, n: int, *,
            t: float | None = None) -> int | None:
        """Vendor SPS endpoint.  Returns None for missing responses (azure)."""
        t = self.now if t is None else t
        if self._missing_rate > 0:
            miss_salt = self.seed if self.vendor is None \
                else f"{self.seed}:{self.vendor}"
            u = _hash_units(f"{miss_salt}:miss:{type_name}:{az}:{int(t)}", 1)[0]
            if u < self._missing_rate:
                return None
        f = self.free(t, np.array([self._pool_idx(type_name, region, az)]))[0]
        if f >= n:
            return 3
        if f >= np.ceil(n / 2):
            return 2
        return 1

    def t3_true(self, type_name: str, region: str, az: str, *,
                t: float | None = None, cap: int = SPS_CAP) -> int:
        t = self.now if t is None else t
        f = self.free(t, np.array([self._pool_idx(type_name, region, az)]))[0]
        return int(np.clip(np.floor(f), 0, cap))

    def request_spot(self, type_name: str, region: str, az: str, n: int, *,
                     launch: bool = True) -> tuple[bool, list[int]]:
        """Spot request at the current market time.  Success iff free >= n."""
        i = self._pool_idx(type_name, region, az)
        f = self.free(self.now, np.array([i]))[0]
        if f < n:
            return False, []
        if not launch:
            return True, []
        ids = []
        for _ in range(n):
            nid = len(self._records)
            self._records.append(NodeRecord(nid, i, self.now))
            self._alive_by_pool.setdefault(i, []).append(nid)
            ids.append(nid)
        self._used[i] += n
        return True, ids

    def terminate(self, node_ids: list[int]) -> None:
        for nid in node_ids:
            rec = self._records[nid]
            if rec.alive:
                rec.end_t = self.now
                rec.reason = "terminated"
                self._used[rec.pool_idx] -= 1
                self._alive_by_pool[rec.pool_idx].remove(nid)

    def node(self, node_id: int) -> NodeRecord:
        """The (live, mutable) record of one launched node."""
        return self._records[node_id]

    # ------------------------------------------------------------------
    # time + interruptions
    # ------------------------------------------------------------------

    def advance(self, to_t: float, check_every: float = 5.0) -> list[NodeRecord]:
        """Advance market time, reclaiming nodes when capacity drops.

        Returns the interruption events emitted during the advance.
        """
        events: list[NodeRecord] = []
        t = self.now
        while t < to_t:
            t = min(t + check_every, to_t)
            active = [i for i, ids in self._alive_by_pool.items() if ids]
            if not active:
                continue
            idx = np.array(active)
            cap = self.capacity(t, idx)
            for pool_i, c in zip(active, cap):
                excess = int(np.ceil(self._used[pool_i] - c))
                if excess <= 0:
                    continue
                alive = self._alive_by_pool[pool_i]
                victims = self._rng.choice(len(alive), size=min(excess, len(alive)),
                                           replace=False)
                victim_ids = [alive[v] for v in sorted(victims, reverse=True)]
                for nid in victim_ids:
                    rec = self._records[nid]
                    rec.end_t = t
                    rec.reason = "interrupted"
                    alive.remove(nid)
                    self._used[pool_i] -= 1
                    events.append(rec)
        self.now = to_t
        self.interruptions.extend(events)
        return events

    def reclaim(self, type_name: str, region: str, az: str, n: int) -> list[NodeRecord]:
        """Force-interrupt up to ``n`` alive nodes of one capacity pool.

        The chaos-replay hook: targeted interruption injection at the current
        market time, independent of the capacity process (which ``advance``
        already models).  Victims are seeded-random, events land in
        :attr:`interruptions` exactly like capacity-driven reclaims, so the
        operator cannot tell the difference — which is the point.
        """
        i = self._pool_idx(type_name, region, az)
        alive = self._alive_by_pool.get(i, [])
        if not alive or n <= 0:
            return []
        victims = self._rng.choice(len(alive), size=min(n, len(alive)),
                                   replace=False)
        events = []
        for nid in [alive[v] for v in sorted(victims, reverse=True)]:
            rec = self._records[nid]
            rec.end_t = self.now
            rec.reason = "interrupted"
            alive.remove(nid)
            self._used[i] -= 1
            events.append(rec)
        self.interruptions.extend(events)
        return events

    def events_since(self, cursor: int) -> tuple[list[NodeRecord], int]:
        """Interruption events after ``cursor``; returns (events, new cursor)."""
        return self.interruptions[cursor:], len(self.interruptions)

    # ------------------------------------------------------------------
    # derived vendor metrics
    # ------------------------------------------------------------------

    def interruption_free_score(self, type_name: str, region: str, *,
                                t: float | None = None) -> int:
        """AWS 'interruption frequency' bucket mapped to 1-3 (SpotVerse's IF).

        Derived from the pool process itself (churn propensity over the past
        30 days) so it exists without requiring our own launch history,
        mirroring the vendor-published aggregate metric.
        """
        t = self.now if t is None else t
        azs = self.catalog.azs(region)
        idx = np.array([self._pool_idx(type_name, region, az) for az in azs])
        # sample the past 30 days at 6h resolution
        ts = np.arange(max(0.0, t - 30 * MINUTES_PER_DAY), t + 1, 360.0)
        caps = np.stack([self.capacity(tt, idx) for tt in ts])  # (T, A)
        mean = caps.mean(0)
        drop = (np.minimum.accumulate(caps[::-1], 0)[::-1] < 0.5 * mean).mean(0)
        churn = float(drop.mean())
        if churn < 0.05:
            return 3
        if churn < 0.20:
            return 2
        return 1

    @property
    def records(self) -> list[NodeRecord]:
        return self._records
