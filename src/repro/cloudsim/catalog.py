"""Instance catalog: types, sizes, regions/AZs, spot prices.

Stands in for the vendor's offering catalog.  Deterministic given a seed, so
every experiment is reproducible.  Scale mirrors the paper's datasets
(~100-1000 instance types across up to 17 regions).

The default tables (:data:`CATEGORIES`, :data:`DEFAULT_REGIONS`,
:data:`REGION_UTC_OFFSET`) model an AWS-like offering; the multi-vendor
scenario engine (``repro.multicloud``) builds per-vendor catalogs by passing
its own ``categories`` / ``regions`` / ``utc_offsets`` tables plus a
``vendor`` tag that salts every deterministic draw, so two vendors (or two
regions of one vendor) with structurally identical configs never share a
price or capacity trace.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

CATEGORIES = {
    "general": {"families": ["m5", "m5a", "m6i", "m7i", "t3"], "gb_per_vcpu": 4.0,
                "od_per_vcpu": 0.048},
    "compute": {"families": ["c5", "c5a", "c6i", "c7i"], "gb_per_vcpu": 2.0,
                "od_per_vcpu": 0.0425},
    "memory": {"families": ["r5", "r5a", "r6i", "r7i"], "gb_per_vcpu": 8.0,
               "od_per_vcpu": 0.063},
    "accelerated": {"families": ["g4dn", "g5", "p3"], "gb_per_vcpu": 4.0,
                    "od_per_vcpu": 0.13},
}

SIZES = {
    "large": 2, "xlarge": 4, "2xlarge": 8, "4xlarge": 16,
    "8xlarge": 32, "12xlarge": 48, "16xlarge": 64, "24xlarge": 96,
}

DEFAULT_REGIONS = {
    "us-east-1": 6, "us-west-2": 4, "eu-west-1": 3, "eu-west-2": 3,
    "ap-northeast-1": 4, "ap-northeast-2": 3, "ap-southeast-1": 3,
    "sa-east-1": 2, "ca-central-1": 2, "eu-central-1": 3, "us-east-2": 3,
    "ap-south-1": 3, "eu-north-1": 2, "ap-southeast-2": 3, "us-west-1": 2,
    "eu-west-3": 2, "me-south-1": 2,
}

# Rough UTC offset (hours) per region — drives the local-nighttime capacity peak.
REGION_UTC_OFFSET = {
    "us-east-1": -5, "us-east-2": -5, "us-west-1": -8, "us-west-2": -8,
    "ca-central-1": -5, "sa-east-1": -3, "eu-west-1": 0, "eu-west-2": 0,
    "eu-west-3": 1, "eu-central-1": 1, "eu-north-1": 1, "me-south-1": 3,
    "ap-south-1": 5.5, "ap-southeast-1": 8, "ap-northeast-1": 9,
    "ap-northeast-2": 9, "ap-southeast-2": 10,
}


def _stable_unit(key: str) -> float:
    """Deterministic uniform(0,1) from a string key (seed-stable hashing)."""
    h = hashlib.blake2b(key.encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") / 2.0 ** 64


@dataclass(frozen=True)
class InstanceType:
    name: str            # e.g. "m5.2xlarge"
    family: str          # "m5"
    category: str        # "general"
    size: str            # "2xlarge"
    vcpus: int
    memory_gb: float


class Catalog:
    """Deterministic instance catalog + spot pricing.

    ``vendor`` (optional) salts every deterministic draw — with it set, two
    catalogs that differ only in vendor produce distinct price fields.
    ``categories`` / ``utc_offsets`` override the AWS-like default tables so
    a vendor profile can bring its own family names and region geography;
    unknown regions fall back to UTC offset 0 as before.  All three default
    to the historical behaviour, so existing seeds reproduce bit-for-bit.
    """

    def __init__(self, seed: int = 0, regions: dict[str, int] | None = None,
                 n_regions: int | None = None, *, vendor: str | None = None,
                 categories: dict | None = None,
                 utc_offsets: dict[str, float] | None = None):
        self.seed = seed
        self.vendor = vendor
        self.categories = dict(categories) if categories is not None \
            else CATEGORIES
        self._offsets = dict(REGION_UTC_OFFSET)
        if utc_offsets is not None:
            self._offsets.update(utc_offsets)
        # every deterministic draw hashes through this salt; vendor=None
        # keeps the pre-multicloud key shape (and therefore every committed
        # benchmark trace) bit-identical
        self._salt = str(seed) if vendor is None else f"{seed}:{vendor}"
        regions = dict(regions or DEFAULT_REGIONS)
        if n_regions is not None:
            regions = dict(list(regions.items())[:n_regions])
        self.regions = regions
        self.types: list[InstanceType] = []
        for cat, spec in self.categories.items():
            for fam in spec["families"]:
                for size, vcpus in SIZES.items():
                    self.types.append(InstanceType(
                        name=f"{fam}.{size}", family=fam, category=cat,
                        size=size, vcpus=vcpus,
                        memory_gb=vcpus * spec["gb_per_vcpu"],
                    ))
        self._by_name = {t.name: t for t in self.types}

    def __len__(self) -> int:
        return len(self.types)

    def get(self, name: str) -> InstanceType:
        return self._by_name[name]

    def azs(self, region: str) -> list[str]:
        return [f"{region}{chr(ord('a') + i)}" for i in range(self.regions[region])]

    def utc_offset(self, region: str) -> float:
        """UTC offset (hours) driving the region's local-nighttime peak."""
        return self._offsets.get(region, 0)

    def pools(self) -> list[tuple[InstanceType, str, str]]:
        """All (type, region, az) capacity pools."""
        out = []
        for r in self.regions:
            for az in self.azs(r):
                for t in self.types:
                    out.append((t, r, az))
        return out

    def spot_price(self, type_name: str, region: str) -> float:
        """$/hr.  Spot = on-demand * (1 - discount), discount in [0.55, 0.88],
        deterministic per (vendor, type, region, seed).  Static over time,
        mirroring the post-2017 low-volatility pricing regime the paper
        describes."""
        t = self._by_name[type_name]
        od = self.categories[t.category]["od_per_vcpu"] * t.vcpus
        u = _stable_unit(f"price:{self._salt}:{type_name}:{region}")
        discount = 0.55 + 0.33 * u
        region_mult = 1.0 + 0.25 * _stable_unit(f"regionprice:{self._salt}:{region}")
        return od * (1.0 - discount) * region_mult

    def on_demand_price(self, type_name: str, region: str) -> float:
        t = self._by_name[type_name]
        od = self.categories[t.category]["od_per_vcpu"] * t.vcpus
        region_mult = 1.0 + 0.25 * _stable_unit(f"regionprice:{self._salt}:{region}")
        return od * region_mult
