"""Rate-limited SPS query service (paper §3: 50 distinct scenarios / 24h / account).

Models the vendor-side constraint that makes USQS/TSTP necessary: each account
may register at most ``scenario_limit`` *distinct* query scenarios per rolling
24 hours, where a scenario is the full (type, region, az, node-count) tuple —
"queries for the same configuration with different node counts are treated as
separate requests".
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from .market import SpotMarket, MINUTES_PER_DAY


class QueryLimitExceeded(RuntimeError):
    pass


@dataclass
class _Account:
    name: str
    scenarios: deque = field(default_factory=deque)  # (t, scenario_key)

    def distinct_in_window(self, now: float) -> set:
        while self.scenarios and self.scenarios[0][0] <= now - MINUTES_PER_DAY:
            self.scenarios.popleft()
        return {k for _, k in self.scenarios}


class SPSQueryService:
    """Front door to :meth:`SpotMarket.sps`, enforcing account scenario quotas."""

    def __init__(self, market: SpotMarket, n_accounts: int = 66,
                 scenario_limit: int = 50,
                 region_limits: dict[str, int] | None = None):
        self.market = market
        self.scenario_limit = scenario_limit
        #: optional per-region cap on distinct scenarios per rolling 24h,
        #: pooled across accounts — models vendors that rate-limit the
        #: *endpoint* per region rather than per account (Azure/GCP style)
        self.region_limits = dict(region_limits or {})
        self._region_log: dict[str, _Account] = {
            r: _Account(f"region-{r}") for r in self.region_limits
        }
        self.accounts = [_Account(f"acct-{i}") for i in range(n_accounts)]
        self.total_queries = 0

    def query(self, type_name: str, region: str, az: str, n: int) -> int | None:
        """Route the query to any account with quota; raise if all exhausted."""
        key = (type_name, region, az, n)
        now = self.market.now
        if region in self.region_limits:
            log = self._region_log[region]
            seen = log.distinct_in_window(now)
            if key not in seen and len(seen) >= self.region_limits[region]:
                raise QueryLimitExceeded(
                    f"region {region} exhausted its "
                    f"{self.region_limits[region]}-scenario/24h quota")
            if key not in seen:
                log.scenarios.append((now, key))
        for acct in self.accounts:
            seen = acct.distinct_in_window(now)
            if key in seen or len(seen) < self.scenario_limit:
                if key not in seen:
                    acct.scenarios.append((now, key))
                self.total_queries += 1
                return self.market.sps(type_name, region, az, n)
        raise QueryLimitExceeded(
            f"all {len(self.accounts)} accounts exhausted their "
            f"{self.scenario_limit}-scenario/24h quota")

    def capacity_remaining(self) -> int:
        now = self.market.now
        return sum(self.scenario_limit - len(a.distinct_in_window(now))
                   for a in self.accounts)
