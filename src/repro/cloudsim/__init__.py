"""Spot-market simulator substrate standing in for the vendor cloud APIs."""
from .catalog import Catalog, InstanceType, CATEGORIES, SIZES, DEFAULT_REGIONS  # noqa: F401
from .market import SpotMarket, SPS_CAP, MINUTES_PER_DAY, MINUTES_PER_WEEK  # noqa: F401
from .sps import SPSQueryService, QueryLimitExceeded  # noqa: F401
from .probes import probe_real_availability, run_interruption_experiment, LifetimeData  # noqa: F401
from .collector import DataCollector, CollectorConfig  # noqa: F401
