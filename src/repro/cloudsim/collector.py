"""The Fig. 3 *Data Collector*: periodic USQS/TSTP collection over all targets.

Drives the paper's collection pipeline against the (rate-limited) SPS service:
every ``period_min`` minutes each tracked (type, region, az) target is probed
at the current USQS target count (or refreshed via TSTP for high-precision
mode), and the reconstructed T3 estimate is appended to the archive.

The archive doubles as the engine's history store: ``to_candidate_set``
assembles the (K, T) T3 matrix + catalog attributes for the scoring window —
the same role the paper's object storage + time-series DB play.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.tstp import TSTPResult, find_transition_points
from ..core.types import CandidateSet
from ..core.usqs import T3Estimator, USQSSampler
from .market import SpotMarket
from .sps import SPSQueryService


@dataclass
class CollectorConfig:
    period_min: float = 10.0
    t_min: int = 5
    t_max: int = 50
    step: int = 5
    mode: str = "usqs"            # "usqs" | "tstp" | "full"
    tstp_early_stop: int = 4


class DataCollector:
    """Maintains per-target T3 archives via the configured query heuristic."""

    def __init__(self, service: SPSQueryService, targets,
                 config: CollectorConfig | None = None):
        self.service = service
        self.market: SpotMarket = service.market
        self.targets = list(targets)               # [(type, region, az)]
        self.cfg = config or CollectorConfig()
        grid = np.arange(self.cfg.t_min, self.cfg.t_max + 1, self.cfg.step)
        self._samplers = {t: USQSSampler(self.cfg.t_min, self.cfg.t_max, self.cfg.step)
                          for t in self.targets}
        self._estimators = {t: T3Estimator(grid) for t in self.targets}
        self._tstp_cache: dict[tuple, TSTPResult] = {}
        self.times: list[float] = []
        self.t3_archive: dict[tuple, list[int]] = {t: [] for t in self.targets}
        self.t2_archive: dict[tuple, list[int]] = {t: [] for t in self.targets}
        self._tick = 0

    # -- one collection cycle ------------------------------------------------

    def collect_once(self) -> None:
        self.times.append(self.market.now)
        for tgt in self.targets:
            ty, rg, az = tgt
            if self.cfg.mode == "usqs":
                tc = self._samplers[tgt].next_target()
                sps = self.service.query(ty, rg, az, tc)
                if sps is not None:   # azure-profile queries may be missing
                    self._estimators[tgt].observe(tc, sps, self._tick)
                self.t3_archive[tgt].append(self._estimators[tgt].t3())
                self.t2_archive[tgt].append(-1)
            elif self.cfg.mode == "tstp":
                res = find_transition_points(
                    lambda n: self.service.query(ty, rg, az, n) or 1,
                    self.cfg.t_min, self.cfg.t_max,
                    cache=self._tstp_cache.get(tgt),
                    early_stop=self.cfg.tstp_early_stop)
                self._tstp_cache[tgt] = res
                self.t3_archive[tgt].append(res.t3)
                self.t2_archive[tgt].append(res.t2)
            else:  # full scan (ground truth; expensive)
                t3 = t2 = 0
                for n in range(self.cfg.t_min, self.cfg.t_max + 1):
                    s = self.service.query(ty, rg, az, n)
                    if s is not None and s >= 3:
                        t3 = n
                    if s is not None and s >= 2:
                        t2 = n
                self.t3_archive[tgt].append(t3)
                self.t2_archive[tgt].append(t2)
        self._tick += 1

    def run(self, cycles: int) -> None:
        for _ in range(cycles):
            self.collect_once()
            self.market.advance(self.market.now + self.cfg.period_min)

    # -- archive -> engine candidate set --------------------------------------

    def to_candidate_set(self, window: int | None = None) -> CandidateSet:
        cat = self.market.catalog
        names, regions, azs, fams, cats, vcpus, mems, prices, rows = \
            [], [], [], [], [], [], [], [], []
        for tgt in self.targets:
            ty, rg, az = tgt
            it = cat.get(ty)
            series = np.asarray(self.t3_archive[tgt], np.float64)
            if window is not None:
                series = series[-window:]
            names.append(ty); regions.append(rg); azs.append(az)
            fams.append(it.family); cats.append(it.category)
            vcpus.append(it.vcpus); mems.append(it.memory_gb)
            prices.append(cat.spot_price(ty, rg))
            rows.append(series)
        return CandidateSet(
            names=np.array(names), regions=np.array(regions), azs=np.array(azs),
            families=np.array(fams), categories=np.array(cats),
            vcpus=np.array(vcpus, np.float64), memory_gb=np.array(mems, np.float64),
            prices=np.array(prices, np.float64), t3=np.stack(rows),
        )
