"""The Fig. 3 *Data Collector*: periodic USQS/TSTP collection over all targets.

Drives the paper's collection pipeline against the (rate-limited) SPS service:
every ``period_min`` minutes each tracked (type, region, az) target is probed
at the current USQS target count (or refreshed via TSTP for high-precision
mode), and the reconstructed T3 estimate is appended to the archive.

The archive doubles as the engine's history store: ``to_candidate_set``
assembles the (K, T) T3 matrix + catalog attributes for the scoring window —
the same role the paper's object storage + time-series DB play.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.tstp import TSTPResult, find_transition_points
from ..core.types import CandidateSet
from ..core.usqs import T3Estimator, USQSSampler
from .market import SpotMarket
from .sps import SPSQueryService


@dataclass
class CollectorConfig:
    period_min: float = 10.0
    t_min: int = 5
    t_max: int = 50
    step: int = 5
    mode: str = "usqs"            # "usqs" | "tstp" | "full"
    tstp_early_stop: int = 4
    #: host-side T3 ring capacity (columns).  When set, the collector keeps
    #: the last N ticks in a preallocated (K, N) ndarray so a bounded-window
    #: ``to_candidate_set(window=...)`` materializes in O(K*window) instead
    #: of rebuilding the full python-list matrix, and ``column(i)`` (the
    #: live-ingestion feed) is an O(K) slice.  None disables the ring.
    ring_capacity: int | None = None
    #: storage dtype of the host ring.  T3 values are small integer node
    #: counts (``<= t_max``), so "float32" / "int16" / "int8" hold them
    #: exactly at 1/2, 1/4 and 1/8 of the float64 footprint — at
    #: (vendor x region x type) catalog scale the host ring is the
    #: collector's dominant allocation, and "int8" is what lets K grow with
    #: the multi-vendor catalog.  ``column`` / ``to_candidate_set`` still
    #: hand out float64, so every consumer sees bit-identical values
    #: regardless of the ring dtype.  "int8" requires ``t_max <= 127``
    #: (validated at construction).
    ring_dtype: str = "float64"
    #: optional :class:`repro.core.usqs.BudgetedProbeScheduler` (or anything
    #: with a ``plan(cycle) -> list[int]`` of target indices).  When set,
    #: each :meth:`DataCollector.collect_once` probes only the planned
    #: targets; the rest carry their current estimate forward without
    #: spending any query budget.  Indices are positions in the collector's
    #: ``targets`` list.  Like the estimators, scheduler state is a monotone
    #: accumulator — a retried tick after a mid-collection raise re-plans
    #: from current staleness.
    scheduler: object | None = None
    #: fault-injection hook, called as ``fault_hook(tick)`` at the start of
    #: every :meth:`DataCollector.collect_once`.  Raising aborts the tick
    #: before anything is probed or appended — the chaos adapter
    #: (``repro.operator.chaos``) models collector outages this way, and the
    #: operator's reconcile loop is what absorbs the raise (bounded retry +
    #: backoff, then a stale-archive warning).  ``None`` disables it.
    fault_hook: object | None = None

    _RING_DTYPES = ("float64", "float32", "int16", "int8")

    def __post_init__(self):
        if self.ring_dtype not in self._RING_DTYPES:
            raise ValueError(
                f"ring_dtype must be one of {self._RING_DTYPES}, "
                f"got {self.ring_dtype!r}")
        if self.ring_dtype == "int8" and self.t_max > 127:
            raise ValueError(
                f"int8 host ring cannot hold T3 values up to t_max={self.t_max} "
                f"exactly (int8 max is 127)")


class DataCollector:
    """Maintains per-target T3 archives via the configured query heuristic."""

    def __init__(self, service: SPSQueryService, targets,
                 config: CollectorConfig | None = None):
        self.service = service
        self.market: SpotMarket = service.market
        self.targets = list(targets)               # [(type, region, az)]
        self.cfg = config or CollectorConfig()
        grid = np.arange(self.cfg.t_min, self.cfg.t_max + 1, self.cfg.step)
        self._samplers = {t: USQSSampler(self.cfg.t_min, self.cfg.t_max, self.cfg.step)
                          for t in self.targets}
        self._estimators = {t: T3Estimator(grid) for t in self.targets}
        self._tstp_cache: dict[tuple, TSTPResult] = {}
        self.times: list[float] = []
        self.t3_archive: dict[tuple, list[int]] = {t: [] for t in self.targets}
        self.t2_archive: dict[tuple, list[int]] = {t: [] for t in self.targets}
        self._tick = 0
        cap = self.cfg.ring_capacity
        # preallocated (K, cap) host ring of the last `cap` T3 columns
        self._ring = (np.zeros((len(self.targets), cap),
                               np.dtype(self.cfg.ring_dtype))
                      if cap else None)
        self._ring_len = 0
        self._static_cols = None     # cached catalog columns (static per run)

    # -- one collection cycle ------------------------------------------------

    def collect_once(self) -> None:
        """One collection cycle over all targets — **atomic** in the archive.

        All per-target probing happens into tick-local buffers; the archive
        (``times`` / ``t3_archive`` / ``t2_archive`` / host ring / ``_tick``)
        is committed only after every target produced a value.  A raise mid
        collection — the configured ``fault_hook``, a rate-limit
        ``QueryLimitExceeded``, a vendor-side error — therefore leaves the
        archive exactly as it was: no target ever holds more columns than
        another, and ``to_candidate_set`` can never assemble a ragged
        window.  (Estimator/TSTP caches may have absorbed partial
        observations before the raise; they are monotone accumulators, so a
        retried tick just continues from them.)
        """
        if self.cfg.fault_hook is not None:
            self.cfg.fault_hook(self._tick)
        planned = (set(self.cfg.scheduler.plan(self._tick))
                   if self.cfg.scheduler is not None else None)
        t3_new: list[int] = []
        t2_new: list[int] = []
        for k, tgt in enumerate(self.targets):
            ty, rg, az = tgt
            if planned is not None and k not in planned:
                # outside this cycle's probe budget: carry the current
                # estimate forward, spend no queries
                if self.cfg.mode == "usqs":
                    t3_new.append(self._estimators[tgt].t3())
                    t2_new.append(-1)
                else:
                    prev3 = self.t3_archive[tgt]
                    prev2 = self.t2_archive[tgt]
                    t3_new.append(prev3[-1] if prev3 else 0)
                    t2_new.append(prev2[-1] if prev2 else -1)
                continue
            if self.cfg.mode == "usqs":
                tc = self._samplers[tgt].next_target()
                sps = self.service.query(ty, rg, az, tc)
                if sps is not None:   # azure-profile queries may be missing
                    self._estimators[tgt].observe(tc, sps, self._tick)
                t3_new.append(self._estimators[tgt].t3())
                t2_new.append(-1)
            elif self.cfg.mode == "tstp":
                res = find_transition_points(
                    lambda n: self.service.query(ty, rg, az, n) or 1,
                    self.cfg.t_min, self.cfg.t_max,
                    cache=self._tstp_cache.get(tgt),
                    early_stop=self.cfg.tstp_early_stop)
                self._tstp_cache[tgt] = res
                t3_new.append(res.t3)
                t2_new.append(res.t2)
            else:  # full scan (ground truth; expensive)
                t3 = t2 = 0
                for n in range(self.cfg.t_min, self.cfg.t_max + 1):
                    s = self.service.query(ty, rg, az, n)
                    if s is not None and s >= 3:
                        t3 = n
                    if s is not None and s >= 2:
                        t2 = n
                t3_new.append(t3)
                t2_new.append(t2)
        # ---- commit (no raises below this line) --------------------------
        self.times.append(self.market.now)
        for tgt, t3, t2 in zip(self.targets, t3_new, t2_new):
            self.t3_archive[tgt].append(t3)
            self.t2_archive[tgt].append(t2)
        if self._ring is not None:
            cap = self._ring.shape[1]
            self._ring[:, self._tick % cap] = t3_new
            self._ring_len = min(self._ring_len + 1, cap)
        self._tick += 1

    def run(self, cycles: int) -> None:
        for _ in range(cycles):
            self.collect_once()
            self.market.advance(self.market.now + self.cfg.period_min)

    # -- archive -> engine candidate set --------------------------------------

    @property
    def ticks(self) -> int:
        """Completed collection cycles (== columns in the full archive)."""
        return self._tick

    def column(self, i: int) -> np.ndarray:
        """The (K,) T3 column of tick ``i`` — the live-ingestion feed.

        O(K) from the host ring when tick ``i`` is still inside it,
        otherwise assembled from the full per-target lists.
        """
        if not -self._tick <= i < self._tick:
            raise IndexError(f"tick {i} not collected yet (have {self._tick})")
        i %= self._tick
        if self._ring is not None and i >= self._tick - self._ring_len:
            return self._ring[:, i % self._ring.shape[1]].astype(np.float64)
        return np.array([self.t3_archive[t][i] for t in self.targets],
                        np.float64)

    def _catalog_columns(self):
        if self._static_cols is None:
            cat = self.market.catalog
            names, regions, azs, fams, cats, vcpus, mems, prices = \
                [], [], [], [], [], [], [], []
            for ty, rg, az in self.targets:
                it = cat.get(ty)
                names.append(ty); regions.append(rg); azs.append(az)
                fams.append(it.family); cats.append(it.category)
                vcpus.append(it.vcpus); mems.append(it.memory_gb)
                prices.append(cat.spot_price(ty, rg))
            self._static_cols = (
                np.array(names), np.array(regions), np.array(azs),
                np.array(fams), np.array(cats),
                np.array(vcpus, np.float64), np.array(mems, np.float64),
                np.array(prices, np.float64))
        return self._static_cols

    def to_candidate_set(self, window: int | None = None) -> CandidateSet:
        """Assemble the (K, T) scoring-window candidate set.

        With a host ring configured (``CollectorConfig.ring_capacity``) and
        a ``window`` the ring still covers, the T3 matrix is two ndarray
        slices — O(K*window) per tick instead of a python-list rebuild of
        the entire history.  Output is identical either way (the regression
        test pins this).
        """
        names, regions, azs, fams, cats, vcpus, mems, prices = \
            self._catalog_columns()
        # window=0 keeps the historical `series[-0:]` (full-history) reading
        w_eff = self._tick if not window else min(window, self._tick)
        if self._ring is not None and 0 < w_eff <= self._ring_len:
            cap = self._ring.shape[1]
            idx = (np.arange(self._tick - w_eff, self._tick)) % cap
            t3 = self._ring[:, idx].astype(np.float64)
        else:
            t3 = np.stack([np.asarray(self.t3_archive[t], np.float64)[
                self._tick - w_eff:] for t in self.targets])
        return CandidateSet(
            names=names, regions=regions, azs=azs, families=fams,
            categories=cats, vcpus=vcpus, memory_gb=mems, prices=prices,
            t3=t3,
        )
